"""Per-target block-size tables — the tuning axis of ``device_op``.

The paper separates *what* a kernel computes (common, portable) from
*how* it is scheduled on a target (target-dependent).  Block/tile sizes
are the scheduling half: the right ``block_q`` for a compiled TPU kernel
is not the right one for the CPU interpreter, and hardcoding ``512`` in
every op signature (the seed state) bakes one target's choice into the
portable layer.

This module is the target-dependent table those defaults move into:

* every ``device_op`` registers wildcard defaults for its tunables
  (``block_q``, ``chunk``, ...) at declaration time;
* targets (or the autotuner, :mod:`repro.core.autotune`) may override
  any entry per ``arch`` or per ``(arch, isa)`` — the most specific
  entry wins, mirroring the OpenMP context-selector scoring used for
  code variants (``core/variant.py``): isa-specific beats arch-specific
  beats wildcard;
* op callers pass ``block_q=None`` (the new signature default) and the
  op resolves the value against the *current* ``TargetContext`` at
  trace time — explicit caller values always win.

``set_block_size`` is the autotuner write-back hook: measure, then
write the winning configuration back for ``(op, param, arch, isa)``
with ``source="autotuned"``.

**Persistence** — tuned configurations survive processes.  The table
round-trips to JSON cache files keyed by target
(``tuning_cache/<arch>[__<isa>].json`` under this package, overridable
via ``$REPRO_TUNING_CACHE_DIR``).  ``repro.kernels`` auto-loads the
caches right after every op registers, so any process that imports the
kernels resolves ``block_*=None`` to the cached winners without
re-tuning; ``serve``/``train`` launchers also call
:func:`load_caches` explicitly at startup.  Entries whose op/param is
no longer registered are dropped with a warning, not a crash.

Provenance (``source`` per entry): ``default`` (declaration wildcard),
``target`` (hand-written per-arch entry in the declaration),
``autotuned`` (measured winner written back by the autotuner),
``override`` (ad-hoc ``set_block_size`` caller).  Only non-``default``
entries are persisted — wildcards are re-derived from declarations.

``python -m repro.core.tuning`` pretty-prints every entry with its
specificity and source.
"""
from __future__ import annotations

import dataclasses
import difflib
import json
import os
import threading
import warnings
from typing import Any, Dict, Iterator, List, Optional, Tuple

from repro.core import context as ctx_mod

__all__ = [
    "TuningTable", "table", "block_size", "set_block_size",
    "register_defaults", "entries", "load_caches", "save_caches",
    "default_cache_dir", "cache_filename",
]

# (op, param, arch, isa) — arch/isa None = wildcard.
_Key = Tuple[str, str, Optional[str], Optional[str]]

#: Known provenance values, least to most interesting.
SOURCES = ("default", "target", "override", "autotuned")

#: Sources owned by kernels/*/ops.py declarations — the "hand defaults"
#: the autotuner measures its baseline against.
DECLARED_SOURCES = ("default", "target")

CACHE_FORMAT = 1
CACHE_ENV = "REPRO_TUNING_CACHE_DIR"


@dataclasses.dataclass(frozen=True)
class _Entry:
    value: Any
    source: str  # "default" | "target" | "override" | "autotuned"


def default_cache_dir() -> str:
    """Cache directory: ``$REPRO_TUNING_CACHE_DIR`` or the in-package
    ``tuning_cache/`` (ships with the repo, so winners travel with it)."""
    env = os.environ.get(CACHE_ENV)
    if env:
        return env
    return os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "tuning_cache")


def cache_filename(arch: str, isa: Optional[str] = None) -> str:
    return f"{arch}__{isa}.json" if isa else f"{arch}.json"


def _specificity(key: _Key) -> str:
    _, _, arch, isa = key
    if isa is not None:
        return "arch+isa"
    if arch is not None:
        return "arch"
    return "wildcard"


class TuningTable:
    """Target-keyed tunable-parameter store with specificity lookup."""

    def __init__(self):
        self._entries: Dict[_Key, _Entry] = {}
        self._lock = threading.Lock()

    # -- registration -----------------------------------------------------
    def register_defaults(self, op: str, params: Dict[str, Any]) -> None:
        """Wildcard defaults, set once at ``device_op`` declaration."""
        with self._lock:
            for name, value in params.items():
                self._entries.setdefault((op, name, None, None),
                                         _Entry(value, "default"))

    def set(self, op: str, param: str, value: Any, *,
            arch: Optional[str] = None, isa: Optional[str] = None,
            source: str = "override") -> None:
        """Install/overwrite an entry.  ``isa`` requires ``arch``.

        This is the autotuning write-back hook: the most specific key
        the tuner can name (op, param, arch, isa) gets the measured
        winner, tagged ``source="autotuned"``.
        """
        if isa is not None and arch is None:
            raise ValueError("isa-specific tuning entries need an arch")
        if source not in SOURCES:
            raise ValueError(f"unknown tuning source {source!r}; "
                             f"known: {SOURCES}")
        with self._lock:
            self._entries[(op, param, arch, isa)] = _Entry(value, source)

    # -- lookup -----------------------------------------------------------
    def lookup(self, op: str, param: str,
               tc: Optional[ctx_mod.TargetContext] = None, *,
               sources: Optional[Tuple[str, ...]] = None) -> Any:
        """Most-specific match for the active target context.

        Specificity (high to low): (arch, isa) > (arch,) > wildcard —
        the same dominance order the variant selector scoring gives
        isa > arch.  ``sources`` restricts which provenances may match
        (e.g. ``DECLARED_SOURCES`` resolves the hand defaults as if no
        autotune write-back had ever happened).
        """
        tc = tc or ctx_mod.current_context()
        arch, isa = tc.device.arch, tc.device.isa
        for key in ((op, param, arch, isa) if isa else None,
                    (op, param, arch, None),
                    (op, param, None, None)):
            if key is None:
                continue
            e = self._entries.get(key)
            if e is not None and (sources is None or e.source in sources):
                return e.value
        raise KeyError(f"no tuning entry for op={op!r} param={param!r} "
                       f"(arch={arch!r}, isa={isa!r}); {self._miss_hint(op)}")

    def _miss_hint(self, op: str) -> str:
        """Nearest registered keys, so a failed lookup names what *is*
        in the table instead of dead-ending."""
        params = sorted({k[1] for k in self._entries if k[0] == op})
        if params:
            return f"registered params for op {op!r}: {params}"
        ops = sorted({k[0] for k in self._entries})
        close = difflib.get_close_matches(op, ops, n=3, cutoff=0.4)
        if close:
            return f"op {op!r} has no entries; nearest registered ops: {close}"
        return f"op {op!r} has no entries; registered ops: {ops[:8]}"

    def remove(self, op: str, param: str, *, arch: Optional[str] = None,
               isa: Optional[str] = None) -> None:
        """Drop one entry (no-op if absent) so lookup falls back to the
        next-most-specific key — the inverse of :meth:`set`."""
        with self._lock:
            self._entries.pop((op, param, arch, isa), None)

    def entries(self, op: Optional[str] = None) -> Iterator[Tuple[_Key, Any]]:
        for key, e in self.items(op):
            yield key, e.value

    def items(self, op: Optional[str] = None) -> Iterator[Tuple[_Key, _Entry]]:
        """Like :meth:`entries` but yields the full entry (value+source)."""
        for key, e in sorted(self._entries.items(),
                             key=lambda kv: tuple(x or "" for x in kv[0])):
            if op is None or key[0] == op:
                yield key, e

    def source_of(self, op: str, param: str, *,
                  arch: Optional[str] = None,
                  isa: Optional[str] = None) -> Optional[str]:
        e = self._entries.get((op, param, arch, isa))
        return e.source if e is not None else None

    # -- snapshot/restore (hermetic tests, tuner dry-runs) -----------------
    def snapshot(self) -> Dict[_Key, _Entry]:
        """An immutable-enough copy of the table state; pair with
        :meth:`restore` to keep tests and tuner dry-runs hermetic."""
        with self._lock:
            return dict(self._entries)

    def restore(self, snap: Dict[_Key, _Entry]) -> None:
        with self._lock:
            self._entries = dict(snap)

    # -- persistence -------------------------------------------------------
    #: Only measured winners and explicit overrides persist.  "default"
    #: and "target" entries are declaration-owned: re-derived from
    #: kernels/*/ops.py at import, so a cache file can never fossilize
    #: a value whose declaration was later edited.
    PERSISTED_SOURCES = ("autotuned", "override")

    def save(self, path: str, *, arch: str, isa: Optional[str] = None
             ) -> int:
        """Write the persistable entries for ``(arch, isa)`` to ``path``.

        One file per target key — the cache directory mirrors the
        table's specificity axis, so loading a file can never change
        another target's resolution.
        """
        rows: List[Dict[str, Any]] = []
        for (op, param, a, i), e in self.items():
            if a == arch and i == isa and e.source in self.PERSISTED_SOURCES:
                rows.append({"op": op, "param": param, "value": e.value,
                             "source": e.source})
        payload = {"format": CACHE_FORMAT, "arch": arch, "isa": isa,
                   "entries": rows}
        path = os.path.abspath(path)
        os.makedirs(os.path.dirname(path), exist_ok=True)
        # Atomic publish: a concurrent reader (another process's
        # import-time load_caches) must never see a truncated file.
        tmp = f"{path}.tmp.{os.getpid()}"
        with open(tmp, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True)
            f.write("\n")
        os.replace(tmp, path)
        return len(rows)

    def save_dir(self, cache_dir: Optional[str] = None) -> List[str]:
        """Persist every arch-specific slice; returns the files written."""
        cache_dir = cache_dir or default_cache_dir()
        targets = sorted({(k[2], k[3]) for k, e in self.items()
                          if k[2] is not None
                          and e.source in self.PERSISTED_SOURCES},
                         key=lambda t: (t[0], t[1] or ""))
        paths = []
        for arch, isa in targets:
            p = os.path.join(cache_dir, cache_filename(arch, isa))
            self.save(p, arch=arch, isa=isa)
            paths.append(p)
        return paths

    def load(self, path: str, *, validate: bool = True) -> int:
        """Load one cache file; returns the number of entries installed.

        Stale entries — an op or param that is no longer registered —
        are dropped with a warning instead of crashing: a cache file
        must never be able to brick an import.
        """
        with open(path) as f:
            payload = json.load(f)
        if payload.get("format") != CACHE_FORMAT:
            warnings.warn(f"tuning cache {path}: unknown format "
                          f"{payload.get('format')!r}; ignoring file")
            return 0
        arch, isa = payload.get("arch"), payload.get("isa")
        if not arch:
            warnings.warn(f"tuning cache {path}: missing arch; ignoring file")
            return 0
        known = _registered_tunables() if validate else None
        # Stage then install: a bad row is skipped with a warning and
        # can never leave the file half-applied.
        staged = []
        for row in payload.get("entries", ()):
            op, param = row.get("op"), row.get("param")
            if known is not None and (op not in known
                                      or param not in known[op]):
                warnings.warn(
                    f"tuning cache {path}: dropping stale entry "
                    f"{op!r}.{param!r} (no longer a registered tunable)")
                continue
            if "value" not in row:
                warnings.warn(f"tuning cache {path}: dropping entry "
                              f"{op!r}.{param!r} with no value")
                continue
            source = row.get("source", "autotuned")
            if source not in self.PERSISTED_SOURCES:
                # declaration-owned or unknown provenance has no
                # business coming from a cache file
                warnings.warn(f"tuning cache {path}: dropping entry "
                              f"{op!r}.{param!r} with source {source!r}")
                continue
            staged.append((op, param, row["value"], source))
        for op, param, value, source in staged:
            self.set(op, param, value, arch=arch, isa=isa, source=source)
        return len(staged)

    # -- introspection -----------------------------------------------------
    def dump(self, op: Optional[str] = None) -> str:
        """Human-readable listing: every entry with specificity+source."""
        header = (f"{'op':<18} {'param':<12} {'arch':<10} {'isa':<8} "
                  f"{'specificity':<11} {'source':<10} value")
        lines = [header, "-" * len(header)]
        for key, e in self.items(op):
            o, p, a, i = key
            lines.append(f"{o:<18} {p:<12} {a or '*':<10} {i or '*':<8} "
                         f"{_specificity(key):<11} {e.source:<10} {e.value}")
        if len(lines) == 2:
            lines.append(f"(no entries{f' for op {op!r}' if op else ''})")
        return "\n".join(lines)


def _registered_tunables() -> Dict[str, set]:
    """op name -> declared tunables, importing the kernel packages so
    the registry is populated before validation.  Late import: op.py
    imports this module at load time; by the time a cache is loaded the
    module graph is complete (or mid-``repro.kernels`` import, where
    every ops.py has already run)."""
    import repro.kernels  # noqa: F401  (self-registers every device_op)
    from repro.core.op import op_registry
    return {name: set(op.tunables) for name, op in op_registry.items()}


#: Process-wide table; ``device_op`` declarations and targets write here.
table = TuningTable()

#: Cache files already applied to ``table`` (abs paths), for idempotence.
_loaded_cache_paths: set = set()

#: Paths being loaded right now — validation imports the kernel
#: packages, whose __init__ re-enters load_caches; this stops the
#: re-entrant pass from double-loading without permanently claiming a
#: path that fails to load.
_loading_cache_paths: set = set()


def block_size(op: str, param: str,
               tc: Optional[ctx_mod.TargetContext] = None) -> Any:
    return table.lookup(op, param, tc)


def set_block_size(op: str, param: str, value: Any, *,
                   arch: Optional[str] = None,
                   isa: Optional[str] = None,
                   source: str = "override") -> None:
    table.set(op, param, value, arch=arch, isa=isa, source=source)


def register_defaults(op: str, params: Dict[str, Any]) -> None:
    table.register_defaults(op, params)


def entries(op: Optional[str] = None):
    return table.entries(op)


def load_caches(cache_dir: Optional[str] = None, *,
                force: bool = False) -> int:
    """Apply every cache file under ``cache_dir`` to the global table.

    Idempotent per path (``repro.kernels`` auto-loads at import; the
    ``serve``/``train`` launchers call this again at startup and get a
    no-op).  Returns the number of entries installed this call.
    """
    cache_dir = cache_dir or default_cache_dir()
    if not os.path.isdir(cache_dir):
        return 0
    n = 0
    for fname in sorted(os.listdir(cache_dir)):
        if not fname.endswith(".json"):
            continue
        path = os.path.abspath(os.path.join(cache_dir, fname))
        if not force and path in _loaded_cache_paths:
            continue
        if path in _loading_cache_paths:
            continue
        _loading_cache_paths.add(path)
        try:
            n += table.load(path)
            # only a successful load claims the path: a file that was
            # momentarily corrupt (e.g. mid-write by a concurrent
            # --write-cache) gets retried by the next load_caches call
            _loaded_cache_paths.add(path)
        except Exception as e:  # a bad cache file must never brick import
            warnings.warn(f"tuning cache {path}: failed to load "
                          f"({type(e).__name__}: {e}); ignoring file")
        finally:
            _loading_cache_paths.discard(path)
    return n


def save_caches(cache_dir: Optional[str] = None) -> List[str]:
    """Persist the global table's arch-specific slices; returns paths."""
    return table.save_dir(cache_dir)


def main(argv=None) -> None:
    """``python -m repro.core.tuning`` — pretty-print the live table."""
    import argparse
    ap = argparse.ArgumentParser(
        description="Dump the tuning table (defaults + caches) with "
                    "specificity and provenance per entry.")
    ap.add_argument("--op", default=None, help="restrict to one op")
    ap.add_argument("--cache-dir", default=None,
                    help="inspect this cache dir INSTEAD of the default "
                         f"(sets ${CACHE_ENV} before the kernels import, "
                         "so the default caches are not layered in)")
    args = ap.parse_args(argv)
    if args.cache_dir:
        os.environ[CACHE_ENV] = args.cache_dir
    import repro.kernels  # noqa: F401  (register every op + auto-load caches)
    print(table.dump(op=args.op))


if __name__ == "__main__":
    # Run the *imported* module's main so the table the kernel
    # declarations populated is the table we print (running a module as
    # __main__ creates a second module object with its own globals).
    from repro.core import tuning as _tuning
    _tuning.main()
