"""DeviceRuntime — the facade Pallas kernels are written against.

This is ``libomptarget-device`` for Pallas: kernels call these entry
points instead of target intrinsics, so one kernel source serves every
target (compiled TPU, CPU interpreter, pure-jnp fallback).  The facade
resolves each primitive through the ``declare_variant`` registry at
trace time; after tracing the chosen implementation is baked into the
jaxpr, so dispatch is zero-cost (parity checked in benchmarks/parity.py).

Worksharing & teams (DESIGN.md §3): an OpenMP *team* maps to a Pallas
grid step; ``#pragma omp for`` over teams maps to block partitioning of
the iteration space across the grid.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import atomics as _atomics
from repro.core import context as _context
from repro.core import intrinsics as _intrinsics
from repro.core import memory as _memory
from repro.obs import profile as _profile
import repro.core.targets  # noqa: F401  (register all variants)

__all__ = ["DeviceRuntime", "runtime", "kernel_call"]


@dataclasses.dataclass(frozen=True)
class DeviceRuntime:
    """Bound runtime for the target context active at construction."""

    ctx: _context.TargetContext

    # -- team / thread hierarchy (omp_get_team_num etc.) -------------------
    @staticmethod
    def team_id(axis: int = 0):
        return pl.program_id(axis)

    @staticmethod
    def num_teams(axis: int = 0):
        return pl.num_programs(axis)

    # -- worksharing (#pragma omp for schedule(static)) ---------------------
    @staticmethod
    def static_partition(total: int, num_teams: int, team: Any) -> Tuple[Any, Any]:
        """Contiguous static schedule: [lo, hi) owned by ``team``."""
        chunk = pl.cdiv(total, num_teams)
        lo = team * chunk
        hi = jnp.minimum(lo + chunk, total)
        return lo, hi

    @staticmethod
    def grid_size(total: int, block: int) -> int:
        return pl.cdiv(total, block)

    # -- memory (allocate directive) ----------------------------------------
    alloc_shared = staticmethod(_memory.alloc_shared)
    alloc_scalar = staticmethod(_memory.alloc_scalar)
    alloc_semaphore = staticmethod(_memory.alloc_semaphore)

    # -- atomics (Listing 3/4) -----------------------------------------------
    atomic_add = staticmethod(_atomics.atomic_add)
    atomic_max = staticmethod(_atomics.atomic_max)
    atomic_min = staticmethod(_atomics.atomic_min)
    atomic_exchange = staticmethod(_atomics.atomic_exchange)
    atomic_cas = staticmethod(_atomics.atomic_cas)
    atomic_inc = staticmethod(_atomics.atomic_inc)

    # -- vector intrinsics (variant-dispatched) -------------------------------
    iota = staticmethod(_intrinsics.iota)
    repeat = staticmethod(_intrinsics.repeat)
    roll = staticmethod(_intrinsics.roll)
    approx_reciprocal = staticmethod(_intrinsics.approx_reciprocal)
    reduce_sum = staticmethod(_intrinsics.reduce_sum)
    reduce_max = staticmethod(_intrinsics.reduce_max)
    make_async_copy = staticmethod(_intrinsics.make_async_copy)

    # -- masking / predication (omp if/masked analogue) ----------------------
    when = staticmethod(pl.when)

    # -- target knobs ---------------------------------------------------------
    def compiler_params(self, dimension_semantics: Optional[Sequence[str]] = None,
                        vmem_limit_bytes: Optional[int] = None):
        return _intrinsics.compiler_params(dimension_semantics, vmem_limit_bytes)

    @property
    def interpret(self) -> bool:
        return self.ctx.interpret

    @property
    def use_pallas(self) -> bool:
        return self.ctx.use_pallas

    @property
    def arch(self) -> str:
        return self.ctx.arch


def runtime() -> DeviceRuntime:
    """Bind a DeviceRuntime to the current target context."""
    return DeviceRuntime(_context.current_context())


def kernel_call(kernel_fn, *, out_shape, grid=None, in_specs=None,
                out_specs=None, scratch_shapes=(), dimension_semantics=None,
                vmem_limit_bytes=None, name=None, rt: Optional[DeviceRuntime] = None,
                num_scalar_prefetch: int = 0, **kwargs):
    """``pallas_call`` with the target decided by the runtime.

    The single entry point kernels launch through — the analogue of the
    kernel-launch glue the device runtime provides.  On the ``generic``
    target callers should not reach this (ops.py dispatches to ref.py);
    calling it anyway falls back to interpret mode so behavior is total.

    ``num_scalar_prefetch``: the leading N operands are small integer
    control arrays (block tables, lengths) made available *before* the
    kernel body runs so BlockSpec index maps can compute data-dependent
    DMA source blocks — the paged-KV gather path.  Index maps then
    receive the prefetched refs as trailing arguments after the grid
    indices.  The interpreter honors the same descriptor, so this stays
    in the common part of the runtime.
    """
    rt = rt or runtime()
    params = rt.compiler_params(dimension_semantics, vmem_limit_bytes)
    pk = dict(kwargs)
    if params is not None:
        pk["compiler_params"] = params
    interpret = rt.interpret or not rt.use_pallas
    if num_scalar_prefetch:
        from jax.experimental.pallas import tpu as pltpu
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=num_scalar_prefetch,
            grid=grid,
            in_specs=list(in_specs) if in_specs is not None else [],
            out_specs=out_specs,
            scratch_shapes=list(scratch_shapes),
        )
        call = pl.pallas_call(
            kernel_fn,
            out_shape=out_shape,
            grid_spec=grid_spec,
            interpret=interpret,
            name=name,
            **pk,
        )
    else:
        call = pl.pallas_call(
            kernel_fn,
            out_shape=out_shape,
            grid=grid,
            in_specs=in_specs if in_specs is not None else [],
            out_specs=out_specs,
            scratch_shapes=list(scratch_shapes),
            interpret=interpret,
            name=name,
            **pk,
        )
    if _profile.enabled():
        # opt-in (REPRO_PROFILE=1) dispatch timer, aggregated into the
        # shared profile registry; the off path pays one bool check
        label = name or getattr(kernel_fn, "__name__", "kernel")
        return _profile.wrap(f"kernel_call.{label}", call)
    return call
