"""Measurement-driven autotuner — the loop that turns the tuning
*table* into a tuning *system*.

The paper's performance argument is that the portable runtime matches
native only once target-dependent scheduling choices (block/tile sizes)
are specialized per architecture.  PR 1 gave those choices a home
(:mod:`repro.core.tuning`, keyed ``(op, param, arch, isa)``) and a
write-back hook (``set_block_size``); this module is what plugs into
the hook:

1. **enumerate** — for any registered :class:`~repro.core.op.DeviceOp`,
   sweep :meth:`~repro.core.op.DeviceOp.candidate_configs`: the
   declared ``search_space`` per tunable, constraint-pruned, baseline
   (the declaration's hand-default resolution) first.
2. **dedup** — kernels clamp block sizes to operand shapes, so at the
   example's scale several candidates can lower to the identical
   program; only the first config per distinct StableHLO lowering is
   measured (ranking identical programs would mine timing noise for a
   fabricated winner), the rest are recorded as aliases.
3. **gate** — a candidate is only eligible if its output matches the
   generic-arch oracle (the op's reference implementation) within the
   op's declared parity tolerances.  A fast-but-wrong schedule must
   never win.
4. **measure** — median-of-``repeats`` walltime after ``warmup`` runs,
   per candidate, under the requested target context.  The measurer is
   injectable so tests can drive the search with a stubbed clock.
5. **write back** — the winner lands in the global table via
   ``set_block_size(..., source="autotuned")``, most-specific key the
   caller named (arch or arch+isa); ``tuning.save_caches()`` then
   persists it for every future process.

Because the baseline config is itself measured as candidate #0 and the
winner is the argmin over eligible candidates, ``tuned_ms <=
baseline_ms`` holds by construction for every op.
"""
from __future__ import annotations

import dataclasses
import hashlib
import statistics
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax

from repro.core import context as ctx_mod
from repro.core import tuning as tuning_mod
from repro.core.op import DeviceOp, compare_outputs

__all__ = [
    "Candidate", "OpTuneResult", "autotune_op", "autotune_all",
    "median_walltime_ms", "outputs_match",
]

#: measurer signature: (run: () -> output, config) -> median milliseconds.
Measurer = Callable[[Callable[[], Any], Dict[str, Any]], float]


@dataclasses.dataclass
class Candidate:
    """One measured (or rejected) configuration."""
    config: Dict[str, Any]
    correct: Optional[bool]          # False = failed the oracle gate
    median_ms: Optional[float]       # None when rejected/errored
    note: str = ""


@dataclasses.dataclass
class OpTuneResult:
    """The autotuner's verdict for one (op, arch, isa) cell."""
    op: str
    arch: str
    isa: Optional[str]
    baseline_config: Dict[str, Any]
    baseline_ms: float
    best_config: Dict[str, Any]
    tuned_ms: float
    candidates: List[Candidate]
    written: bool

    @property
    def speedup(self) -> float:
        return self.baseline_ms / self.tuned_ms if self.tuned_ms else 1.0

    def to_json(self) -> Dict[str, Any]:
        return {
            "op": self.op, "arch": self.arch, "isa": self.isa,
            "baseline_config": self.baseline_config,
            "baseline_ms": round(self.baseline_ms, 4),
            "winning_config": self.best_config,
            "tuned_ms": round(self.tuned_ms, 4),
            "speedup": round(self.speedup, 3),
            "candidates_measured": sum(1 for c in self.candidates
                                       if c.median_ms is not None),
            "candidates_rejected": sum(1 for c in self.candidates
                                       if c.correct is False),
            "candidates_aliased": sum(1 for c in self.candidates
                                      if c.correct is None
                                      and c.median_ms is None),
            "written": self.written,
        }


def median_walltime_ms(run: Callable[[], Any], *, repeats: int = 3,
                       warmup: int = 1) -> float:
    """Default measurer: median-of-``repeats`` after ``warmup`` calls
    (the warmup absorbs compilation; results are blocked on inside
    ``run``, so perf_counter brackets real device work)."""
    for _ in range(max(warmup, 0)):
        run()
    times = []
    for _ in range(max(repeats, 1)):
        t0 = time.perf_counter()
        run()
        times.append((time.perf_counter() - t0) * 1e3)
    return statistics.median(times)


def outputs_match(got, want, tol: Dict[str, float]) -> bool:
    """The correctness gate — delegates to the single comparison
    implementation shared with the parity suite
    (:func:`repro.core.op.compare_outputs`)."""
    return compare_outputs(got, want, tol)["within_tol"]


def _make_runner(op: DeviceOp, operands: Tuple, merged: Dict[str, Any],
                 arch: str, isa: Optional[str]
                 ) -> Tuple[Callable[[], Any], Callable[[], str]]:
    """``(run, lowered)`` for one candidate: ``run`` executes the op
    jitted under the target context and blocks on the result (built
    once per candidate so repeated measurement calls hit the jit cache
    instead of re-tracing); ``lowered`` returns the StableHLO text of
    the same program, used to detect candidates that collapse to an
    identical kernel after shape clamping."""
    @jax.jit
    def jitted(*ops):
        return op(*ops, **merged)

    def run():
        with ctx_mod.target(arch, isa=isa):
            out = jitted(*operands)
        return jax.block_until_ready(out)

    def lowered() -> str:
        with ctx_mod.target(arch, isa=isa):
            return jitted.lower(*operands).as_text()

    return run, lowered


def autotune_op(op: DeviceOp, *, arch: str, isa: Optional[str] = None,
                key=None, budget: Optional[int] = None,
                repeats: int = 3, warmup: int = 1,
                measurer: Optional[Measurer] = None,
                write_back: bool = True) -> OpTuneResult:
    """Search, gate, measure, and (optionally) write back one op's
    tunables for ``(arch, isa)``.  See the module docstring for the
    loop; ``measurer`` is injectable for stubbed-clock tests."""
    if not op.tunables:
        raise ValueError(f"op {op.name!r} has no tunables to search")
    key = key if key is not None else jax.random.PRNGKey(0)
    measure = measurer or (
        lambda run, cfg: median_walltime_ms(run, repeats=repeats,
                                            warmup=warmup))

    operands, params = op.example_inputs(key)
    # Oracle: the reference under the generic arch — the "new target
    # for free" path is also the ground truth every schedule must hit.
    with ctx_mod.target(ctx_mod.ARCH_GENERIC):
        want = jax.block_until_ready(op.ref_call(operands, params))

    # Baseline = the *declaration's* resolution (wildcard/hand-target
    # entries only).  Resolving against the full table would measure a
    # previous autotune run's cached winner against itself, collapsing
    # every regenerated trajectory to 1.00x.
    with ctx_mod.target(arch, isa=isa) as tc:
        base_cfg = {
            p: (params[p] if params.get(p) is not None
                else tuning_mod.table.lookup(
                    op.name, p, tc, sources=tuning_mod.DECLARED_SOURCES))
            for p in op.tunables}

    candidates: List[Candidate] = []
    best: Optional[Candidate] = None
    baseline_ms: Optional[float] = None
    seen_lowerings: Dict[str, Dict[str, Any]] = {}
    for i, cfg in enumerate(op.candidate_configs(base=base_cfg,
                                                 budget=budget)):
        merged = dict(params)
        merged.update(cfg)
        run, lowered = _make_runner(op, operands, merged, arch, isa)
        # Alias dedup: kernels clamp block sizes to the operand shapes,
        # so at example scale several candidates can lower to the
        # *identical* program.  Ranking those against each other would
        # measure pure noise — only the first config of each distinct
        # lowering is measured, the rest are recorded as aliases.
        try:
            digest = hashlib.sha256(
                lowered().encode("utf-8")).hexdigest()
        except Exception:
            digest = None          # let run() surface the real error
        if digest is not None and digest in seen_lowerings:
            rep = seen_lowerings[digest]
            candidates.append(Candidate(
                cfg, None, None,
                f"aliases {rep['cfg']} after clamping "
                f"(identical lowering; not separately measured)"))
            continue
        try:
            got = run()
        except Exception as e:  # illegal schedule the constraints missed
            candidates.append(Candidate(cfg, False, None,
                                        f"error: {type(e).__name__}: {e}"))
            continue
        if not outputs_match(got, want, op.tol):
            candidates.append(Candidate(cfg, False, None,
                                        "rejected: fails oracle parity"))
            continue
        if digest is not None:
            seen_lowerings[digest] = {"cfg": dict(cfg)}
        ms = measure(run, cfg)
        cand = Candidate(cfg, True, ms)
        candidates.append(cand)
        if i == 0:
            baseline_ms = ms       # candidate #0 is the baseline config
        if best is None or ms < best.median_ms:
            best = cand
    if best is None:
        raise RuntimeError(
            f"autotune {op.name!r} on arch={arch!r}: every candidate "
            f"failed the correctness gate "
            f"({[c.note for c in candidates]})")
    if baseline_ms is None:       # baseline itself was rejected
        baseline_ms = best.median_ms

    written = False
    if write_back:
        # Only searched params were measured; an unsearched tunable's
        # resolved default must not be pinned as an arch-specific
        # "autotuned" entry (it would shadow later declaration edits).
        for p, v in best.config.items():
            if p in op.search_space:
                tuning_mod.set_block_size(op.name, p, v, arch=arch,
                                          isa=isa, source="autotuned")
                written = True
    return OpTuneResult(op=op.name, arch=arch, isa=isa,
                        baseline_config=base_cfg, baseline_ms=baseline_ms,
                        best_config=dict(best.config),
                        tuned_ms=best.median_ms,
                        candidates=candidates, written=written)


def autotune_all(ops: Sequence[DeviceOp], *, archs: Sequence[str],
                 isa: Optional[str] = None, budget: Optional[int] = None,
                 repeats: int = 3, warmup: int = 1,
                 measurer: Optional[Measurer] = None,
                 write_back: bool = True,
                 progress: Optional[Callable[[str], None]] = None
                 ) -> List[OpTuneResult]:
    """Sweep ``ops`` × ``archs``; skips tunable-less ops."""
    results = []
    for arch in archs:
        for op in ops:
            if not op.tunables:
                continue
            if progress:
                progress(f"tuning {op.name} on {arch}"
                         f"{f'/{isa}' if isa else ''} ...")
            results.append(autotune_op(
                op, arch=arch, isa=isa, budget=budget, repeats=repeats,
                warmup=warmup, measurer=measurer, write_back=write_back))
    return results
