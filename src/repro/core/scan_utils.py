"""Memory-bounded scans for recurrent layers.

``lax.scan`` saves every per-step carry for the backward pass.  For
matrix-memory recurrences (mLSTM's (B,H,Dk,Dv) cell, mamba's
(B,d_inner,d_state) state) that is catastrophic at training shapes —
the xlstm-1.3b train_4k dry-run measured ~360 GiB/device of scan
residuals.  ``chunked_scan`` nests two scans with ``jax.checkpoint`` on
the inner one: only chunk-boundary carries are saved (S/chunk of them)
and in-chunk steps are recomputed during backward — the standard
O(sqrt(S))-memory scan remat.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["chunked_scan"]


def chunked_scan(f, init, xs, *, chunk: int = 64):
    """Drop-in lax.scan with chunk-boundary-only carry saving.

    xs leaves must share leading length S.  Falls back to plain scan
    when S does not divide into chunks (or is small)."""
    length = jax.tree_util.tree_leaves(xs)[0].shape[0]
    if chunk <= 1 or length < 2 * chunk or length % chunk:
        return jax.lax.scan(f, init, xs)
    n_chunks = length // chunk

    def split(x):
        return x.reshape((n_chunks, chunk) + x.shape[1:])

    xs_c = jax.tree_util.tree_map(split, xs)

    @jax.checkpoint
    def inner(carry, xc):
        return jax.lax.scan(f, carry, xc)

    carry, ys_c = jax.lax.scan(inner, init, xs_c)

    def join(y):
        return y.reshape((length,) + y.shape[2:])

    return carry, jax.tree_util.tree_map(join, ys_c)
