"""Portable atomic operations (paper §3.1, Listing 3).

The paper shows that four of the five atomics the device runtime needs
(add, max, exchange, cas) can be written portably with
``#pragma omp atomic [compare] capture seq_cst`` — only ``inc`` needs a
target intrinsic.

TPU adaptation (DESIGN.md §3): Pallas grid steps are *sequential* on a
core, so a read-modify-write on a VMEM/SMEM ref **is** atomic with
respect to other grid steps; the portable forms below therefore lower to
exactly the load/op/store a native kernel would emit — the IR-identity
claim of §4.1, checked by benchmarks/parity.py.  Cross-core atomicity is
the shard_map/collective layer's job, not the kernel's.

Every function returns the *captured* old value, matching the
``capture`` clause semantics in Listing 3.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.variant import declare_target

__all__ = [
    "atomic_add", "atomic_max", "atomic_min", "atomic_exchange",
    "atomic_cas", "atomic_inc",
]


def _read(ref, idx):
    return ref[...] if idx is None else ref[idx]


def _write(ref, idx, v):
    if idx is None:
        ref[...] = v
    else:
        ref[idx] = v


@declare_target
def atomic_add(ref, value, idx=None):
    """{ V = *X; *X += E; } return V;   (atomic capture seq_cst)"""
    v = _read(ref, idx)
    _write(ref, idx, v + value)
    return v


@declare_target
def atomic_max(ref, value, idx=None):
    """{ V = *X; if (*X < E) *X = E; } return V;  (atomic compare capture)"""
    v = _read(ref, idx)
    _write(ref, idx, jnp.maximum(v, value))
    return v


@declare_target
def atomic_min(ref, value, idx=None):
    v = _read(ref, idx)
    _write(ref, idx, jnp.minimum(v, value))
    return v


@declare_target
def atomic_exchange(ref, value, idx=None):
    """{ V = *X; *X = E; } return V;"""
    v = _read(ref, idx)
    val = jnp.broadcast_to(jnp.asarray(value, v.dtype), v.shape) if hasattr(v, "shape") else value
    _write(ref, idx, val)
    return v


@declare_target
def atomic_cas(ref, expected, desired, idx=None):
    """{ V = *X; if (*X == E) *X = D; } return V;"""
    v = _read(ref, idx)
    _write(ref, idx, jnp.where(v == expected, desired, v))
    return v


@declare_target
def atomic_inc(ref, bound, idx=None):
    """CUDA-semantics wraparound increment: { v = x; x = x >= e ? 0 : x+1 }.

    In the paper this is the one op OpenMP 5.1 cannot express and stays
    target-specific.  On TPU the sequential-grid model lets the same RMW
    express it portably — an assumption that *changed in our favor*
    (DESIGN.md §7): the base implementation is total, no variant needed.
    """
    v = _read(ref, idx)
    _write(ref, idx, jnp.where(v >= bound, jnp.zeros_like(v), v + 1))
    return v
