"""The paper's headline claim, live: adding a new execution target costs
"a few compiler intrinsics rather than a reimplementation of the entire
runtime" (§1).

Here we register a brand-new target arch at runtime — 'emulator', a
stand-in for a future accelerator — by providing ONLY the two intrinsics
whose portable fallback we want to override.  Every kernel in the repo
then runs on it unchanged via the generic lowering path.

Run:  PYTHONPATH=src python examples/new_target.py
"""
import jax
import jax.numpy as jnp

import repro.core.context as ctx
from repro.core import intrinsics as I
from repro.core.variant import arch, declare_variant, match

# -- 1. teach the context about the new arch (one tuple entry) --------------
ctx.KNOWN_ARCHS = ctx.KNOWN_ARCHS + ("emulator",)

# -- 2. the target-specific part: two variants, nothing else ----------------

TRACE = {"approx_reciprocal": 0, "iota": 0}


@declare_variant(I.approx_reciprocal, match=match(device=arch("emulator")))
def _recip_emulated(x):
    TRACE["approx_reciprocal"] += 1
    # e.g. a Newton-Raphson refinement an emulated ISA might need
    y = 1.0 / x
    return y * (2.0 - x * y) * jnp.where(x != 0, 1.0, 1.0)


@declare_variant(I.iota, match=match(device=arch("emulator")))
def _iota_emulated(shape, dim, dtype=jnp.int32):
    TRACE["iota"] += 1
    return jax.lax.broadcasted_iota(dtype, shape, dim)


def main():
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.rmsnorm.ops import rmsnorm

    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (1, 2, 64, 32))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 2, 64, 32))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 2, 64, 32))
    x = jax.random.normal(key, (16, 128))
    w = jnp.ones((128,)) * 0.1

    with ctx.target("emulator"):
        # variant dispatch picks the emulator intrinsics...
        r = I.approx_reciprocal(jnp.asarray([2.0, 4.0]))
        ii = I.iota((4, 8), 1)
        # ...and whole kernels run unchanged through the portable base
        out_attn = flash_attention(q, k, v)
        out_norm = rmsnorm(x, w)

    assert TRACE["approx_reciprocal"] == 1 and TRACE["iota"] == 1, TRACE
    assert float(jnp.abs(r - jnp.asarray([0.5, 0.25])).max()) < 1e-6
    assert ii.shape == (4, 8)

    with ctx.target("interpret"):
        ref_attn = flash_attention(q, k, v)
        ref_norm = rmsnorm(x, w)

    e1 = float(jnp.abs(out_attn - ref_attn).max())
    e2 = float(jnp.abs(out_norm - ref_norm).max())
    print(f"flash_attention emulator-vs-interpret max|diff| = {e1:.2e}")
    print(f"rmsnorm        emulator-vs-interpret max|diff| = {e2:.2e}")
    assert e1 < 1e-4 and e2 < 1e-4
    print("new target ran every kernel with 2 variant overrides "
          f"(dispatches observed: {TRACE}) and zero kernel-source changes.")


if __name__ == "__main__":
    main()
