"""Quickstart: the portable runtime end-to-end in ~60 lines.

1. Write ONE kernel against the DeviceRuntime facade.
2. Run it on two targets (CPU interpreter / pure-jnp generic) without
   touching the source — the paper's portability claim.
3. Train a tiny assigned-architecture model for a few steps.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import context as ctx
from repro.core.runtime import kernel_call, runtime


# -- 1. a portable kernel ----------------------------------------------------

def scaled_softmax_rows(x):
    """Row softmax with runtime-dispatched intrinsics."""
    rt = runtime()
    rows, cols = x.shape

    def kern(x_ref, o_ref):
        v = x_ref[...]
        m = rt.reduce_max(v, axis=1, keepdims=True)
        e = jnp.exp(v - m)
        denom = rt.reduce_sum(e, axis=1, keepdims=True)
        o_ref[...] = e * rt.approx_reciprocal(denom)

    if not rt.use_pallas:        # generic target: plain XLA ops
        m = x.max(axis=1, keepdims=True)
        e = jnp.exp(x - m)
        return e / e.sum(axis=1, keepdims=True)

    return kernel_call(
        kern,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        grid=(rows // 8,),
        in_specs=[pl.BlockSpec((8, cols), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((8, cols), lambda i: (i, 0)),
        name="quickstart_softmax",
    )(x)


def main():
    x = jax.random.normal(jax.random.PRNGKey(0), (32, 128))

    # -- 2. same source, two targets ----------------------------------------
    with ctx.target("interpret"):
        y_interp = scaled_softmax_rows(x)
    with ctx.target("generic"):
        y_generic = scaled_softmax_rows(x)
    err = float(jnp.abs(y_interp - y_generic).max())
    print(f"interpret vs generic max|diff| = {err:.2e}")
    assert err < 1e-5

    # -- 3. train a reduced assigned architecture ----------------------------
    from repro.configs.base import ShapeConfig
    from repro.configs.smoke import smoke_config
    from repro.train import TrainConfig, Trainer

    cfg = smoke_config("gemma2-2b", num_layers=2)
    shape = ShapeConfig("quickstart", seq_len=32, global_batch=4,
                        kind="train")
    tc = TrainConfig(steps=5, peak_lr=3e-3, warmup_steps=1)
    hist = Trainer(cfg, shape, tc).run()["history"]
    print("losses:", [round(h["loss"], 3) for h in hist])


if __name__ == "__main__":
    main()
