"""Fault-tolerance demo: train, die mid-run, restart, verify the resumed
run matches an uninterrupted one step-for-step (atomic checkpoints +
deterministic data replay).

Run:  PYTHONPATH=src python examples/train_restart.py
"""
import shutil
import tempfile

import numpy as np

from repro.configs.base import ShapeConfig
from repro.configs.smoke import smoke_config
from repro.train import SimulatedFailure, TrainConfig, Trainer

SHAPE = ShapeConfig("demo", seq_len=32, global_batch=4, kind="train")


def main():
    cfg = smoke_config("granite-8b", num_layers=2)
    work = tempfile.mkdtemp(prefix="repro_ckpt_")
    try:
        # uninterrupted reference
        ref = Trainer(cfg, SHAPE, TrainConfig(
            steps=8, ckpt_dir=work + "/ref", ckpt_every=4)).run()["history"]

        # node dies at step 6 (after the step-4 checkpoint committed)
        try:
            Trainer(cfg, SHAPE, TrainConfig(
                steps=8, ckpt_dir=work + "/ft", ckpt_every=4,
                fail_at_step=6)).run()
        except SimulatedFailure as e:
            print(f"!! {e} — restarting from the last atomic checkpoint")

        resumed = Trainer(cfg, SHAPE, TrainConfig(
            steps=8, ckpt_dir=work + "/ft", ckpt_every=4)).run()["history"]
        print(f"resumed at step {resumed[0]['step']}")

        ref_tail = [h["loss"] for h in ref if h["step"] >= resumed[0]["step"]]
        res_tail = [h["loss"] for h in resumed]
        np.testing.assert_allclose(ref_tail, res_tail, rtol=2e-4, atol=2e-4)
        print("resumed losses match the uninterrupted run:",
              [round(x, 4) for x in res_tail])
    finally:
        shutil.rmtree(work, ignore_errors=True)


if __name__ == "__main__":
    main()
