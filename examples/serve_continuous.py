"""Continuous-batching serving demo: more requests than KV slots; the
engine admits from the queue as slots free, one decode step at a time.

Run:  PYTHONPATH=src python examples/serve_continuous.py
"""
import time

import jax
import numpy as np

from repro.configs.smoke import smoke_config
from repro.models.registry import build_model
from repro.serve import Engine, Request, ServeConfig


def main():
    cfg = smoke_config("deepseek-v2-lite-16b")   # MoE + MLA serving
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = Engine(model, params, ServeConfig(
        slots=2, cache_len=48, max_new_tokens=6))

    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    tokens=rng.integers(0, cfg.vocab_size,
                                        size=4 + 2 * i).tolist())
            for i in range(5)]
    t0 = time.perf_counter()
    engine.run_to_completion(reqs)
    dt = time.perf_counter() - t0
    for r in reqs:
        print(f"req {r.rid}: prompt_len={len(r.tokens)} -> out={r.out}")
    toks = sum(len(r.out) for r in reqs)
    print(f"{toks} tokens in {dt:.1f}s ({toks / dt:.1f} tok/s, 2 slots, "
          f"{len(reqs)} requests)")
    assert all(r.done for r in reqs)


if __name__ == "__main__":
    main()
