"""Continuous-batching serving demo: more requests than KV slots; the
engine admits from the queue as slots free (batched prefill per
prompt-length group) and decodes all slots in one jitted step against a
*paged* KV cache — the slot engine run alongside shows the two cache
layouts produce identical greedy outputs, and a third run over an
**int8-quantized** paged pool (``kv_dtype="int8"``, repro.quant) shows
quantized serving finishes the same stream in the same order on half
the pool bytes.  A fourth run forces **oversubscription** (3 usable
pages vs a 12-page working set, 0.25x): the preempt/requeue scheduler
checkpoints victims and re-prefills them, and the outputs stay
token-identical to the unconstrained paged run.  A fifth run turns on
**self-speculative decoding** (``spec_mode="ngram"``): the engine
drafts 4 tokens per step from each sequence's own history, verifies
them in one batched paged-decode call, rolls rejected tokens back by
truncating the block-table suffix — and still emits exactly the plain
paged run's tokens in the same finish order.

Run:  PYTHONPATH=src python examples/serve_continuous.py
"""
import time

import jax
import numpy as np

from repro.configs.smoke import smoke_config
from repro.models.registry import build_model
from repro.serve import (Engine, Request, ServeConfig,
                         run_recording_finish_order)


def _requests(cfg):
    rng = np.random.default_rng(0)
    return [Request(rid=i,
                    tokens=rng.integers(0, cfg.vocab_size,
                                        size=4 + 2 * i).tolist())
            for i in range(5)]


def _run(engine, reqs):
    """Drive the engine to completion, recording rid finish order."""
    t0 = time.perf_counter()
    order = run_recording_finish_order(engine, reqs)
    dt = time.perf_counter() - t0
    assert all(r.done for r in reqs)
    return order, dt


def main():
    cfg = smoke_config("deepseek-v2-lite-16b")   # MoE + MLA serving
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    results, orders = {}, {}
    modes = (("paged", dict(paged=True)),
             ("slot", dict(paged=False)),
             ("int8", dict(paged=True, kv_dtype="int8")),
             # 3 usable pages vs a 12-page working set (2 slots x 6
             # pages of 8): decode pressure forces preempt/requeue
             ("oversub", dict(paged=True, page_size=8, total_pages=4,
                              preempt_policy="lru")),
             # n-gram self-drafting: accepted drafts batch several
             # tokens into one verification step, rejections roll the
             # block table back — outputs must not change
             ("spec", dict(paged=True, spec_mode="ngram", spec_k=4)))
    for label, kw in modes:
        engine = Engine(model, params, ServeConfig(
            slots=2, cache_len=48, max_new_tokens=6, **kw))
        reqs = _requests(cfg)
        orders[label], dt = _run(engine, reqs)
        results[label] = [r.out for r in reqs]
        toks = sum(len(r.out) for r in reqs)
        if label == "paged":
            for r in reqs:
                print(f"req {r.rid}: prompt_len={len(r.tokens)} "
                      f"-> out={r.out}")
            print(f"({engine.page_size}-token pages, "
                  f"{engine.allocator.total_pages} in pool)")
        if label == "int8":
            print(f"(int8 pools: {engine.kv_spec.dtype} storage, "
                  f"per-page-per-head scales)")
        if label == "oversub":
            st = engine.stats()
            assert st["preemptions"] > 0, "oversub run never preempted"
            print(f"(pool of {st['total_pages'] - 1} usable pages vs a "
                  f"12-page working set: {st['preemptions']} preemptions, "
                  f"peak {st['peak_in_use']} pages in use)")
        if label == "spec":
            st = engine.stats()
            acc = st["spec_emitted"] / max(st["spec_steps"], 1)
            print(f"(k=4 drafts/step: {acc:.2f} accepted tokens/step, "
                  f"{st['spec_rejections']} rollbacks)")
        print(f"{label:<7}: {toks} tokens in {dt:.1f}s ({toks / dt:.1f} "
              f"tok/s, 2 slots, {len(reqs)} requests)")

    assert results["paged"] == results["slot"], "paged/slot outputs diverged"
    print("paged == slot outputs: OK")
    # Quantization may perturb logits within the documented tolerance,
    # so the int8 contract is scheduling-level: the same requests finish
    # in the same order with the same budgets as the bf16 paged run.
    assert orders["int8"] == orders["paged"], \
        f"int8 finish order diverged: {orders}"
    assert [len(o) for o in results["int8"]] == \
        [len(o) for o in results["paged"]]
    print("int8 finish order == paged finish order: OK")
    # Preemption must be semantically invisible under greedy decoding:
    # the oversubscribed run re-prefills its victims yet emits exactly
    # the unconstrained run's tokens.
    assert results["oversub"] == results["paged"], \
        "oversubscribed outputs diverged from the unconstrained run"
    print("oversub (0.25x pages, preempt/requeue) == paged outputs: OK")
    # Speculation is a pure batching transform under greedy decoding:
    # every accepted draft equals the token the argmax chain would have
    # produced, so outputs and finish order match the plain paged run.
    assert results["spec"] == results["paged"], \
        "speculative outputs diverged from the plain paged run"
    assert orders["spec"] == orders["paged"], \
        f"spec finish order diverged: {orders}"
    print("spec (ngram k=4, block-table rollback) == paged outputs: OK")


if __name__ == "__main__":
    main()
