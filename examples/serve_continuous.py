"""Continuous-batching serving demo: more requests than KV slots; the
engine admits from the queue as slots free (batched prefill per
prompt-length group) and decodes all slots in one jitted step against a
*paged* KV cache — the slot engine run alongside shows the two cache
layouts produce identical greedy outputs.

Run:  PYTHONPATH=src python examples/serve_continuous.py
"""
import time

import jax
import numpy as np

from repro.configs.smoke import smoke_config
from repro.models.registry import build_model
from repro.serve import Engine, Request, ServeConfig


def _requests(cfg):
    rng = np.random.default_rng(0)
    return [Request(rid=i,
                    tokens=rng.integers(0, cfg.vocab_size,
                                        size=4 + 2 * i).tolist())
            for i in range(5)]


def main():
    cfg = smoke_config("deepseek-v2-lite-16b")   # MoE + MLA serving
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    results = {}
    for paged in (True, False):
        engine = Engine(model, params, ServeConfig(
            slots=2, cache_len=48, max_new_tokens=6, paged=paged))
        reqs = _requests(cfg)
        t0 = time.perf_counter()
        engine.run_to_completion(reqs)
        dt = time.perf_counter() - t0
        assert all(r.done for r in reqs)
        results[paged] = [r.out for r in reqs]
        toks = sum(len(r.out) for r in reqs)
        label = "paged" if paged else "slot "
        if paged:
            for r in reqs:
                print(f"req {r.rid}: prompt_len={len(r.tokens)} "
                      f"-> out={r.out}")
            print(f"({engine.page_size}-token pages, "
                  f"{engine.allocator.total_pages} in pool)")
        print(f"{label}: {toks} tokens in {dt:.1f}s ({toks / dt:.1f} tok/s, "
              f"2 slots, {len(reqs)} requests)")
    assert results[True] == results[False], "paged/slot outputs diverged"
    print("paged == slot outputs: OK")


if __name__ == "__main__":
    main()
